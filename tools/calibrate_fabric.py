"""Fabric calibration CLI (ISSUE 8): fit ``FabricModel`` terms from
measured JAX microbenchmarks, berkeley-ert style.

The fabric prices every hop as ``t = alpha * latency + size / (beta *
bandwidth)`` (:mod:`repro.core.fabric`).  ``alpha`` and ``beta`` default
to 1 — the *nominal* link specs.  This tool closes the model-vs-machine
gap in three stages:

1. **sweep** — run real JAX transfer / collective microbenchmarks over a
   size ladder (1 KiB .. 64 MiB, median of repeats).  On a machine without
   accelerators the sweep runs against the XLA host platform
   (``--devices N`` forks it into N virtual devices before JAX imports);
   the numbers then calibrate the *host* fabric class, which is still
   enough to exercise the full fit + gate pipeline end to end.
2. **fit** — least squares on the hop model.  ``t = alpha*l + u/beta``
   with ``u = size/bw_nominal`` is linear in ``(alpha, 1/beta)``, so the
   2x2 normal equations solve it exactly (:func:`fit_alpha_beta`).
   ``beta`` is **clamped to <= 1**: the coarse search tier's
   per-hop/connectivity caps price candidates at *nominal* bandwidths
   (``docs/search.md``), so a fitted efficiency above 1 would let the
   calibrated simulator undercut the bound and break the
   cascade==exhaustive argmin identity.  Per-class roofline ceilings
   (empirical peak GB/s and GFLOP/s per edge/device class) come from the
   ERT rule — the best sustained rate over the sweep
   (:func:`roofline_terms`).
3. **gate** — re-price a measured composite step (transfer + reduce)
   through the calibrated fabric and fail (exit 1) when the simulated
   vs measured relative error exceeds ``--gate``.

The fit math is pure stdlib (no JAX import), so it is unit-testable
offline — ``tests/test_calibrate_fabric.py`` feeds it synthetic sweeps —
and ``--fit-only samples.json`` re-fits a recorded sweep without touching
JAX at all.  Sample records are ``{"size", "bw", "lat", "t"}`` plus an
optional ``"cls"`` tag.

Usage::

    PYTHONPATH=src python tools/calibrate_fabric.py --devices 8 \
        --out calib.json
    PYTHONPATH=src python tools/calibrate_fabric.py --fit-only sweep.json

Apply the result::

    from repro.core import calibrated, set_default_fabric
    set_default_fabric(calibrated(fit["alpha"], fit["beta"]))
"""

from __future__ import annotations

import argparse
import json
import math
import os
import statistics
import sys
import time
from pathlib import Path

# nominal host-platform link spec the sweep is fitted against; the fitted
# alpha/beta absorb whatever the real host interconnect does relative to it
HOST_NOMINAL_BW = 10e9        # B/s
HOST_NOMINAL_LAT = 5e-6       # s

SIZES = [1 << k for k in range(10, 27, 2)]       # 1 KiB .. 64 MiB
REPEATS = 5


# ---------------------------------------------------------------------------
# Fit math (pure stdlib — unit-testable without JAX)
# ---------------------------------------------------------------------------


def fit_alpha_beta(samples: list[dict], *, clamp_beta: float = 1.0
                   ) -> tuple[float, float]:
    """Least-squares fit of ``t = alpha*lat + size/(beta*bw)`` over
    ``samples`` (dicts with ``size``, ``bw``, ``lat``, ``t``).

    Linear in ``x = (alpha, 1/beta)`` with design rows ``(lat_i,
    size_i/bw_i)``; solved via the 2x2 normal equations.  ``alpha`` is
    floored at 0 and ``beta`` capped at ``clamp_beta`` (default 1 — the
    admissibility ceiling: the search tier's coarse caps assume the sim
    never prices a hop *faster* than its nominal bandwidth)."""
    if not samples:
        raise ValueError("no samples to fit")
    a11 = a12 = a22 = b1 = b2 = 0.0
    for s in samples:
        l, u, t = float(s["lat"]), float(s["size"]) / float(s["bw"]), \
            float(s["t"])
        a11 += l * l
        a12 += l * u
        a22 += u * u
        b1 += l * t
        b2 += u * t
    det = a11 * a22 - a12 * a12
    if abs(det) < 1e-30:
        # degenerate sweep (single size, or zero latency everywhere):
        # fall back to the bandwidth-only fit
        inv_beta = b2 / a22 if a22 > 0 else 1.0
        alpha = 1.0
    else:
        alpha = (b1 * a22 - b2 * a12) / det
        inv_beta = (a11 * b2 - a12 * b1) / det
    alpha = max(0.0, alpha)
    beta = 1.0 / inv_beta if inv_beta > 0 else clamp_beta
    return alpha, min(beta, clamp_beta)


def roofline_terms(samples: list[dict]) -> dict[str, dict[str, float]]:
    """Per-class empirical roofline ceilings, berkeley-ert style: the best
    sustained bandwidth (and, where ``flops`` is recorded, compute rate)
    each class achieved anywhere on the sweep — plus its efficiency
    against the nominal spec, capped at 1."""
    out: dict[str, dict[str, float]] = {}
    for s in samples:
        cls = s.get("cls", "host")
        row = out.setdefault(cls, {"peak_bw": 0.0, "peak_flops": 0.0,
                                   "bw_eff": 0.0})
        t = float(s["t"])
        if t <= 0:
            continue
        bw = float(s["size"]) / t
        if bw > row["peak_bw"]:
            row["peak_bw"] = bw
            row["bw_eff"] = min(1.0, bw / float(s["bw"]))
        if s.get("flops"):
            row["peak_flops"] = max(row["peak_flops"], float(s["flops"]) / t)
    return out


def fit_report(samples: list[dict], *, gate: float | None = None,
               measured_step: float | None = None) -> dict:
    """The full fit artifact: alpha/beta, per-class rooflines, residuals,
    and (when a measured composite step is supplied) the sim-vs-measured
    gate verdict."""
    alpha, beta = fit_alpha_beta(samples)
    resid = []
    for s in samples:
        pred = alpha * float(s["lat"]) + float(s["size"]) / (beta *
                                                             float(s["bw"]))
        resid.append(abs(pred - float(s["t"])) / max(float(s["t"]), 1e-12))
    report = {
        "alpha": alpha,
        "beta": beta,
        "n_samples": len(samples),
        "median_residual": statistics.median(resid) if resid else 0.0,
        "classes": roofline_terms(samples),
    }
    if measured_step is not None:
        pred = predict_step(samples, alpha, beta)
        err = abs(pred - measured_step) / max(measured_step, 1e-12)
        report["step"] = {"measured_s": measured_step, "simulated_s": pred,
                          "rel_error": err}
        if gate is not None:
            report["step"]["gate"] = gate
            report["step"]["passed"] = err <= gate
    return report


def predict_step(samples: list[dict], alpha: float, beta: float) -> float:
    """Price the composite gate step — one max-size transfer per sampled
    class — on the calibrated hop model (what the simulator would charge
    for the same traffic on an uncontended fabric)."""
    per_cls: dict[str, dict] = {}
    for s in samples:
        cur = per_cls.get(s.get("cls", "host"))
        if cur is None or float(s["size"]) > float(cur["size"]):
            per_cls[s.get("cls", "host")] = s
    total = 0.0
    for s in per_cls.values():
        total += alpha * float(s["lat"]) + float(s["size"]) / (beta *
                                                               float(s["bw"]))
    return total


# ---------------------------------------------------------------------------
# JAX microbenchmark sweep
# ---------------------------------------------------------------------------


def _time_op(fn, *, repeats: int = REPEATS) -> float:
    """Median wall time of ``fn()`` (which must block on completion)."""
    fn()                                       # warm-up / compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts)


def run_sweep(n_devices: int, *, sizes: list[int] | None = None) -> list[dict]:
    """Measure device-to-device transfers and mesh all-reduces on the JAX
    host platform; returns fit-ready sample dicts."""
    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={n_devices}")
    import jax
    import jax.numpy as jnp

    devs = jax.devices()
    if len(devs) < 2:
        raise SystemExit("calibration needs >= 2 devices "
                         "(pass --devices N for the host platform)")
    samples: list[dict] = []
    for size in sizes or SIZES:
        n = max(1, size // 4)
        x = jax.device_put(jnp.zeros((n,), jnp.float32), devs[0])

        def xfer(x=x):
            jax.device_put(x, devs[1]).block_until_ready()

        t = _time_op(xfer)
        samples.append({"size": float(n * 4), "bw": HOST_NOMINAL_BW,
                        "lat": HOST_NOMINAL_LAT, "t": t, "cls": "host",
                        "kind": "transfer"})

        # ring all-reduce over every device: 2(p-1)/p of the buffer
        # crosses each link — record the per-link traffic so the hop fit
        # sees comparable units
        p = len(devs)
        y = jnp.zeros((p, n), jnp.float32)

        def reduce(y=y):
            jax.pmap(lambda v: jax.lax.psum(v, "i"), axis_name="i")(
                y).block_until_ready()

        t = _time_op(reduce)
        samples.append({"size": float(n * 4) * 2 * (p - 1) / p,
                        "bw": HOST_NOMINAL_BW, "lat": HOST_NOMINAL_LAT * p,
                        "t": t, "cls": "host", "kind": "allreduce"})
    return samples


def measure_step(n_devices: int) -> float:
    """Wall time of the composite gate step: the largest sweep transfer
    plus its all-reduce, back to back (what ``predict_step`` re-prices)."""
    import jax
    import jax.numpy as jnp

    devs = jax.devices()
    n = max(1, SIZES[-1] // 4)
    x = jax.device_put(jnp.zeros((n,), jnp.float32), devs[0])
    p = len(devs)
    y = jnp.zeros((p, n), jnp.float32)

    def step():
        jax.device_put(x, devs[1]).block_until_ready()
        jax.pmap(lambda v: jax.lax.psum(v, "i"), axis_name="i")(
            y).block_until_ready()

    return _time_op(step)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv: list[str] | None = None) -> int:
    """CLI entry point: sweep (or load), fit, report, gate (exit 1 when
    the sim-vs-measured step error exceeds ``--gate``)."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--devices", type=int, default=8,
                    help="host-platform device count for the sweep")
    ap.add_argument("--fit-only", metavar="SAMPLES_JSON",
                    help="skip the JAX sweep; fit a recorded sample file")
    ap.add_argument("--gate", type=float, default=0.75,
                    help="max allowed sim-vs-measured step relative error")
    ap.add_argument("--no-gate", action="store_true",
                    help="report the step error but never fail on it")
    ap.add_argument("--out", metavar="JSON",
                    help="write the fit report (and raw samples) here")
    args = ap.parse_args(argv)

    if args.fit_only:
        payload = json.loads(Path(args.fit_only).read_text())
        samples = payload["samples"] if isinstance(payload, dict) else payload
        measured = payload.get("measured_step") \
            if isinstance(payload, dict) else None
    else:
        samples = run_sweep(args.devices)
        measured = measure_step(args.devices)

    report = fit_report(samples, gate=None if args.no_gate else args.gate,
                        measured_step=measured)

    print(f"fit over {report['n_samples']} samples: "
          f"alpha={report['alpha']:.4g} beta={report['beta']:.4g} "
          f"(median residual {report['median_residual']:.1%})")
    for cls, row in report["classes"].items():
        print(f"  class {cls}: peak {row['peak_bw'] / 1e9:.2f} GB/s "
              f"({row['bw_eff']:.0%} of nominal)")
    step = report.get("step")
    if step:
        verdict = "" if "passed" not in step else \
            ("  [ok]" if step["passed"] else "  [FAIL]")
        print(f"step gate: measured {step['measured_s'] * 1e3:.2f} ms, "
              f"simulated {step['simulated_s'] * 1e3:.2f} ms, "
              f"error {step['rel_error']:.1%}{verdict}")

    if args.out:
        Path(args.out).write_text(json.dumps(
            {"report": report, "samples": samples,
             "measured_step": measured}, indent=2) + "\n")
        print(f"wrote {args.out}")

    if step and step.get("passed") is False:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
